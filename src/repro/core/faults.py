"""Fault tolerance & straggler mitigation for ODYS sets (DESIGN.md §7).

The paper (§3.1) defers fault tolerance to Osprey-style replication:
multiple ODYS sets (full engine replicas) plus a middleware that remaps
work between sets.  We implement the corresponding mechanics natively:

- **set-granular failover**: the query router keeps a health mask over
  ODYS sets; queries headed to a dead set are re-routed to the healthiest
  surviving set (queries are stateless, the index is replicated — exactly
  why the paper's replica design makes failover trivial);
- **speculative re-dispatch (straggler mitigation)**: the partitioning
  method (core/slave_max.py) gives the expected slave max; any shard
  exceeding ``slo_factor x`` that estimate is assumed straggling and its
  *document partition* is speculatively re-issued to the replica set; the
  query completes at ``min(straggler, re-dispatch latency)``;
- **checkpoint/restart** for index shards lives in
  :mod:`repro.training.checkpoint` (shared with train state).

The router here is an *analytical simulator* driven by per-(query, shard)
latency samples — the same objects the perf model consumes — so mitigation
policies can be evaluated for 1000+-node deployments without hardware.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SetHealth:
    """Liveness mask over ODYS sets, with change notification.

    ``listeners`` are called as ``listener(set_id, alive)`` on every
    *actual* transition (a repeated ``fail`` on a dead set notifies no
    one) — the serving router's health-transition metrics hang off this.
    """

    n_sets: int
    alive: np.ndarray  # bool[n_sets]
    listeners: list = dataclasses.field(
        default_factory=list, repr=False, compare=False
    )

    @classmethod
    def all_alive(cls, n_sets: int) -> "SetHealth":
        return cls(n_sets, np.ones(n_sets, dtype=bool))

    def subscribe(self, listener) -> None:
        if listener not in self.listeners:
            self.listeners.append(listener)

    def unsubscribe(self, listener) -> None:
        if listener in self.listeners:
            self.listeners.remove(listener)

    def _set(self, set_id: int, value: bool) -> None:
        if bool(self.alive[set_id]) == value:
            return
        self.alive[set_id] = value
        for listener in self.listeners:
            listener(set_id, value)

    def fail(self, set_id: int) -> None:
        self._set(set_id, False)

    def recover(self, set_id: int) -> None:
        self._set(set_id, True)


def route_queries(
    n_queries: int, health: SetHealth, seed: int = 0
) -> np.ndarray:
    """Assign each query to an alive ODYS set (uniform over survivors)."""
    alive_ids = np.flatnonzero(health.alive)
    if alive_ids.size == 0:
        raise RuntimeError("no ODYS set alive")
    rng = np.random.default_rng(seed)
    return alive_ids[rng.integers(0, alive_ids.size, size=n_queries)]


@dataclasses.dataclass(frozen=True)
class SpeculationPolicy:
    """Re-dispatch a shard's work when it exceeds slo_factor x expected max."""

    slo_factor: float = 1.5
    redispatch_overhead: float = 2e-3  # seconds: re-RPC + queue re-entry


def query_latency_with_speculation(
    shard_latencies: np.ndarray,      # float[n_queries, ns] primary set
    replica_latencies: np.ndarray,    # float[n_queries, ns] replica set
    expected_max: float,              # partitioning-method estimate
    policy: SpeculationPolicy,
) -> tuple[np.ndarray, np.ndarray]:
    """Response time per query with speculative re-dispatch.

    A query completes when every shard's partition has answered — from the
    primary, or (for shards past the SLO) from the replica launched at the
    SLO deadline.  Returns (latency[n_queries], speculation_rate).
    """
    slo = policy.slo_factor * expected_max
    straggling = shard_latencies > slo
    completed = np.where(
        straggling,
        np.minimum(
            shard_latencies,
            slo + policy.redispatch_overhead + replica_latencies,
        ),
        shard_latencies,
    )
    return completed.max(axis=1), float(straggling.mean())


def degraded_recall_mask(ns: int, dead_shards: list[int]) -> np.ndarray:
    """Availability fallback *within* a set (no replica): serve from
    surviving shards only.  Results stay correct per-shard; global recall
    degrades by ~len(dead)/ns — the striped partitioning (index.py)
    guarantees the loss is rank-uniform, not rank-biased."""
    alive = np.ones(ns, dtype=bool)
    alive[dead_shards] = False
    return alive
