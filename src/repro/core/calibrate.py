"""Closed-loop calibration of the hybrid perf model from the live engine.

The paper's hybrid model (§4) is analytic for the master and network and
*experimental* for the slaves; §5.1 fits the analytic constants (Table 3)
by measuring the real system.  :mod:`repro.core.perfmodel` ships Table 3
verbatim, but those numbers describe a 2012 Odysseus cluster — not this
JAX engine.  This module is the missing measurement half for *our* system:

- :func:`measure_service_times` times the slave phase
  (:func:`~repro.core.parallel.slave_topk_unmerged`) against the full
  pipeline (:func:`~repro.core.parallel.distributed_query_topk`) on the
  same batch; the difference is the measured per-query master service time
  (Formula (4)'s ``ST_master``), and the per-repetition slave timings feed
  the paper's partitioning method (§4.2, Fig 9) for the expected slave max.
- :func:`fit_merge_constants` measures the master's top-k merge at several
  merge widths and least-squares Formula (7)
  ``T_merge = k * (ceil(log2 ns) * t_comparison + t_base)`` for the two
  loser-tree constants.
- :func:`calibrate_from_engine` assembles a fitted
  :class:`~repro.core.perfmodel.MasterParams`: the merge constants from the
  fit, the fixed/per-slave split of the residual master overhead by an
  attribution ratio (documented below), context-switch cost zero (the
  in-process engine has no RPC thread switches), and unmeasured top-k rows
  extrapolated with the paper's Table 3 ratios.

``benchmarks/bench_serving.py`` closes the loop: it sweeps arrival rates
through the scheduler's open-loop replay and reports measured vs
model-projected response time with Formula (18) estimation error, using
the :class:`Calibration` produced here — never ``PAPER_TABLE3``.
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine import make_query_batch
from repro.core.index import INVALID_DOC
from repro.core.parallel import (
    _row_topk,
    distributed_query_topk,
    slave_topk_unmerged,
)
from repro.core.perfmodel import (
    KS,
    SINGLE_10_ONLY,
    MasterParams,
    NetworkParams,
    OdysPerfModel,
    PAPER_TABLE3_MASTER,
    engine_cluster,
    sojourn,
)
from repro.core.slave_max import partitioning_method

# Attribution of the k=10 master overhead between the fixed per-query part
# (T_parent_proc) and the per-slave part ((T_child_proc+rpc)*ns): a single
# measured ns cannot separate them, so we follow the paper's own Table 3
# proportions, where the parent's fixed cost dominates at small ns.
_PARENT_FRACTION = 0.8

_FLOOR = 1e-8  # seconds; keeps fitted params positive and queues stable


def _timed(fn, *args, reps: int = 3, **kw) -> list[float]:
    """Per-repetition wall times (seconds) after one warmup/compile call."""
    jax.block_until_ready(fn(*args, **kw))
    out = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        out.append(time.perf_counter() - t0)
    return out


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted model parameters + the raw measurements behind them."""

    master: MasterParams
    network: NetworkParams
    ns: int
    st_slave: dict        # per-k measured slave service time / query (s)
    st_master: dict       # per-k measured master service time / query (s)
    slave_max: dict       # per-k partitioning-method E[slave max] (s)
    t_comparison: float
    t_base: float
    n_sets: int = 1       # replicated sets the arrival stream spreads over

    def with_sets(self, n_sets: int) -> "Calibration":
        """Same fitted parameters projected at ``n_sets`` replicated sets:
        Formula (17) spreads the arrival stream as ``lam / n_sets`` (§5.2).
        The multi-set bench sweep uses this to project each slice count
        from one calibration."""
        return dataclasses.replace(self, n_sets=int(n_sets))

    def slave_max_time(self, sct: str, k: int, lam: float, ns: int) -> float:
        """The hybrid's experimental half for Formula (17), load-aware.

        The in-process mesh runs one batch at a time, so the slave tier is
        a single deterministic server at the measured per-query service
        time: its sojourn under the set's arrival rate is the M/D/1
        Formula (13), and the measured partitioning-method max inflates it
        by the calibration-time max/mean ratio (§4.2's disk-variance
        spread, here the shard-lockstep spread).  Unmeasured k falls back
        to the nearest measured k.
        """
        del sct, ns
        kk = k if k in self.slave_max else min(
            self.slave_max, key=lambda m: abs(m - k)
        )
        st = self.st_slave[kk]
        inflation = self.slave_max[kk] / max(st, _FLOOR)
        return sojourn(lam / self.n_sets, st) * inflation

    def projected_response(
        self,
        lam: float,
        *,
        batch_size: int = 1,
        max_wait: float = 0.0,
        mix=SINGLE_10_ONLY,
    ) -> float:
        """Formula (17) projection at arrival rate ``lam``, plus the
        micro-batcher's expected formation delay.

        This is the single code path both validation surfaces use:
        ``benchmarks/bench_serving.py`` reports it offline against the
        replay measurements, and the online
        :class:`~repro.obs.residual.ModelResidualMonitor` compares it
        against live spans — so the two Formula (18) errors agree by
        construction.

        The formation term is the mean residual wait of a Poisson arrival
        in a size-``batch_size`` batch former, capped by the formation
        deadline: ``min(max_wait, (batch_size - 1) / (2 lam))``.
        """
        model = OdysPerfModel(master=self.master, network=self.network)
        cluster = engine_cluster(self.ns, n_sets=self.n_sets)
        base = model.total_response_time(lam, cluster, mix, self.slave_max_time)
        formation = (
            min(max_wait, (batch_size - 1) / (2.0 * lam))
            if batch_size > 1 else 0.0
        )
        return base + formation


def fit_merge_constants(
    *,
    k_values=(10, 50),
    widths=(2, 4, 8),
    q: int = 8,
    reps: int = 3,
    backend: str = "jnp",
    interpret: bool | None = None,
    seed: int = 0,
) -> tuple[float, float, dict]:
    """Fit Formula (7)'s (t_comparison, t_base) from measured merges.

    Times the master's per-row best-k reduction (the same ``_row_topk``
    the tournament/allgather merges run) over ``widths`` candidate sets of
    ``w * k`` each, then least-squares the loser-tree cost model
    ``T = k * (ceil(log2 w) * t_cmp + t_base)`` per query.
    """
    rng = np.random.default_rng(seed)
    rows_x, rows_y, raw = [], [], {}
    for k in k_values:
        for w in widths:
            cands = jnp.asarray(
                np.sort(rng.integers(0, 2**30, size=(q, w * k)))
                .astype(np.int32)
            )
            merge = jax.jit(partial(_row_topk, k=k, backend=backend,
                                    interpret=interpret))
            per_q = min(_timed(merge, cands, reps=reps)) / q
            raw[(k, w)] = per_q
            rows_x.append([k * math.ceil(math.log2(w)), k])
            rows_y.append(per_q)
    sol, *_ = np.linalg.lstsq(
        np.asarray(rows_x, dtype=np.float64),
        np.asarray(rows_y, dtype=np.float64),
        rcond=None,
    )
    t_cmp = max(float(sol[0]), _FLOOR)
    t_base = max(float(sol[1]), _FLOOR)
    return t_cmp, t_base, raw


def measure_service_times(
    index,
    meta,
    mesh,
    *,
    ns: int,
    k: int,
    window: int = 1024,
    t_max: int = 2,
    q: int = 8,
    reps: int = 4,
    backend: str = "jnp",
    interpret: bool | None = None,
    merge: str = "tournament",
    seed: int = 0,
) -> tuple[float, float, np.ndarray]:
    """Measure (st_slave, st_master, slave_samples) per query at top-``k``.

    ``st_slave`` is the slave-phase service time (no merge); ``st_master``
    is the **full master path** — query-batch construction, dispatch, the
    distributed merge, and host-side result extraction, i.e. everything
    the serving executor does per batch — minus the slave phase: the live
    analogue of Formula (4), where the paper's ``T_parent_proc`` is
    likewise the master's own per-query processing.  ``slave_samples`` is
    the per-repetition slave-time series, repetition-major, ready for the
    partitioning method (§4.2 Step 1.2 builds exactly this sequence).
    """
    rng = np.random.default_rng(seed)
    vocab_head = max(2, min(64, meta.vocab_size))
    queries = [([int(t)], None)
               for t in rng.integers(0, vocab_head, size=q)]
    qb = make_query_batch(queries, t_max=t_max, meta=meta)
    common = dict(mesh=mesh, ns=ns, k=k, window=window,
                  backend=backend, interpret=interpret)

    def master_path(qs):
        """What the serving executor runs per batch (scheduler.py)."""
        batch = make_query_batch(qs, t_max=t_max, meta=meta)
        res = distributed_query_topk(index, batch, merge=merge, **common)
        docs = np.asarray(res.docids)
        hits = np.asarray(res.n_hits)
        return [
            ([int(d) for d in row if d != INVALID_DOC], int(h))
            for row, h in zip(docs, hits)
        ]

    slave_times = _timed(slave_topk_unmerged, index, qb, reps=reps, **common)
    e2e_times = _timed(master_path, queries, reps=reps)
    st_slave = min(slave_times) / q
    st_master = max(min(e2e_times) / q - st_slave, _FLOOR)
    # One slave-max sample per repetition x shard; the mesh runs shards in
    # lockstep, so per-shard sojourn == the measured slave-phase time.
    samples = np.repeat(np.asarray(slave_times) / q, ns)[None, :]
    return st_slave, st_master, samples


def calibrate_from_engine(
    index,
    meta,
    mesh,
    *,
    ns: int,
    k_values=(10, 50),
    window: int = 1024,
    t_max: int = 2,
    q: int = 8,
    reps: int = 4,
    backend: str = "jnp",
    interpret: bool | None = None,
    merge: str = "tournament",
    n_sets: int = 1,
    seed: int = 0,
) -> Calibration:
    """Fit a :class:`MasterParams` from live-engine measurements.

    ``k_values`` must include 10 (the unit query every weight in
    §4.1.3 is normalized against).  Top-k rows the caller does not measure
    (e.g. k=1000 on a small CI corpus) are extrapolated with the paper's
    Table 3 ratios and marked by their absence from ``st_master``.
    """
    assert 10 in k_values, "the unit query (k=10) must be measured"
    t_cmp, t_base, _ = fit_merge_constants(
        k_values=k_values, q=q, reps=reps, backend=backend,
        interpret=interpret, seed=seed,
    )
    st_slave, st_master, slave_max = {}, {}, {}
    for k in k_values:
        s, m, samples = measure_service_times(
            index, meta, mesh, ns=ns, k=k, window=window, t_max=t_max,
            q=q, reps=max(reps, ns), backend=backend, interpret=interpret,
            merge=merge, seed=seed + k,
        )
        st_slave[k] = s
        st_master[k] = m
        slave_max[k] = float(partitioning_method(samples, ns).mean())

    # Formula (4) decomposition at the measured ns: subtract the fitted
    # merge cost, then split the residual overhead into the fixed parent
    # part and the per-slave RPC part by the attribution ratio.
    log_ns = math.ceil(math.log2(ns)) if ns > 1 else 0
    residual = {
        k: max(st_master[k] - k * (log_ns * t_cmp + t_base), _FLOOR)
        for k in k_values
    }
    t_parent = max(_PARENT_FRACTION * residual[10], _FLOOR)
    rpc = {
        k: max((residual[k] - t_parent) / ns, _FLOOR) for k in k_values
    }
    paper_rpc = PAPER_TABLE3_MASTER.T_master_rpc
    for k in KS:
        if k not in rpc:  # extrapolate with the paper's Table 3 ratio
            rpc[k] = rpc[10] * paper_rpc[k] / paper_rpc[10]
    master = MasterParams(
        T_parent_proc=t_parent,
        T_child_proc=0.0,
        T_master_rpc=dict(rpc),
        t_comparison=t_cmp,
        t_base=t_base,
        # No RPC thread context switches in-process: the term is inert,
        # but the ncs tables keep Table 3's structure for reporting.
        t_per_context_switch=0.0,
        ncs_base=dict(PAPER_TABLE3_MASTER.ncs_base),
        ncs_per_slave=dict(PAPER_TABLE3_MASTER.ncs_per_slave),
        alpha=PAPER_TABLE3_MASTER.alpha,
    )
    # In-process "network": a shared-memory hop.  Equal epsilon rows keep
    # every w_network weight at 1 and the network queue at ~zero load.
    network = NetworkParams(ST_network={k: 1e-9 for k in KS})
    return Calibration(
        master=master, network=network, ns=ns,
        st_slave=st_slave, st_master=st_master, slave_max=slave_max,
        t_comparison=t_cmp, t_base=t_base, n_sets=n_sets,
    )
