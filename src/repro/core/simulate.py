"""Discrete-event simulator of the ODYS pipeline (the "prototype" role).

The paper validates its hybrid model against a real 5-node build (Fig 11).
Offline, this simulator plays the prototype: masters (CPU + memory-bus
stages), shared-nothing slaves, and network hubs are FIFO queues with the
same service-time structure the analytic model assumes; per-(query, slave)
service times come from :class:`CalibratedSlaveModel` noise (or measured
JAX-engine latencies).  bench_fig11 then:

  1. "measures" mean response time from the DES,
  2. predicts it with Formula (17): analytic master/network + the
     partitioning method applied to the DES-observed slave sojourns,
  3. reports the estimation error (paper: <=0.59%).

FIFO single-server queues need no event heap: completion_i =
max(arrival_i, completion_{i-1}) + service_i, per server.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.perfmodel import ClusterConfig, MasterParams, NetworkParams, QueryMix
from repro.core.slave_max import CalibratedSlaveModel


@dataclasses.dataclass
class SimResult:
    arrivals: np.ndarray         # (n,)
    response: np.ndarray         # (n,) total response time per query
    master_part: np.ndarray      # (n,) master sojourn
    network_part: np.ndarray     # (n,) network-stage tail wait
    slave_sojourn: np.ndarray    # (n, ns) per-slave sojourn (queue + service)
    kinds: list                  # (sct, k) per query

    @property
    def mean_response(self) -> float:
        return float(self.response.mean())


def _fifo(arrival: np.ndarray, service: np.ndarray, server: np.ndarray):
    """Sequential FIFO recurrence per pre-assigned server id."""
    completion = np.zeros_like(arrival)
    last = {}
    order = np.argsort(arrival, kind="stable")
    for i in order:
        s = server[i]
        start = max(arrival[i], last.get(s, 0.0))
        completion[i] = start + service[i]
        last[s] = completion[i]
    return completion


def _fifo_multi(arrival: np.ndarray, service: np.ndarray, c: int):
    """FIFO queue with c identical servers (heap of free times)."""
    import heapq

    completion = np.zeros_like(arrival)
    free = [0.0] * c
    heapq.heapify(free)
    order = np.argsort(arrival, kind="stable")
    for i in order:
        t = heapq.heappop(free)
        start = max(arrival[i], t)
        completion[i] = start + service[i]
        heapq.heappush(free, completion[i])
    return completion


def simulate(
    lam: float,
    n_queries: int,
    cluster: ClusterConfig,
    mix: QueryMix,
    master: MasterParams,
    network: NetworkParams,
    slave_model: CalibratedSlaveModel,
    *,
    seed: int = 0,
    slave_services: np.ndarray | None = None,   # (n, ns) measured overrides
    kinds: list | None = None,   # fix the query set across repetitions
) -> SimResult:
    rng = np.random.default_rng(seed)
    c = cluster
    if kinds is None:
        kinds_all = list(mix.qmr.keys())
        probs = np.array([mix.qmr[k] for k in kinds_all])
        choice = rng.choice(len(kinds_all), size=n_queries, p=probs)
        kinds = [kinds_all[i] for i in choice]
    assert len(kinds) == n_queries
    ks = np.array([k for (_, k) in kinds])

    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n_queries))

    # --- master: ncm*nm CPU servers then nm memory-bus servers -----------
    st_m = np.array([master.ST_master(k, c.ns) for k in ks])
    cpu_ids = np.arange(n_queries) % (c.nm * c.ncm)
    bus_ids = cpu_ids % c.nm
    cpu_done = _fifo(arrivals, st_m * master.alpha, cpu_ids)
    bus_done = _fifo(cpu_done, st_m * (1.0 - master.alpha), bus_ids)
    master_part = bus_done - arrivals

    # --- slaves: every slave processes every query (broadcast) -----------
    if slave_services is None:
        slave_services = np.empty((n_queries, c.ns))
        for i, (sct, k) in enumerate(kinds):
            mu = np.log(slave_model.mean(sct, k, 0.0)) - slave_model.sigma**2 / 2
            slave_services[i] = rng.lognormal(mu, slave_model.sigma, size=c.ns)
    slave_done = np.zeros((n_queries, c.ns))
    for s in range(c.ns):
        # Each slave node runs c.nps Odysseus processes (paper §5.1).
        slave_done[:, s] = _fifo_multi(bus_done, slave_services[:, s], c.nps)
    slave_sojourn = slave_done - bus_done[:, None]

    # --- network hubs: ns results per query, slave s -> hub s % nh -------
    st_n = np.array([network.ST_network[k] for k in ks])
    ev_time = slave_done.reshape(-1)
    ev_query = np.repeat(np.arange(n_queries), c.ns)
    ev_hub = np.tile(np.arange(c.ns) % c.nh, n_queries)
    ev_svc = np.repeat(st_n, c.ns)
    hub_done = _fifo(ev_time, ev_svc, ev_hub)
    per_query_done = hub_done.reshape(n_queries, c.ns).max(axis=1)
    del ev_query  # (kept for clarity: event rows are (time, query, hub))

    response = per_query_done - arrivals
    network_part = per_query_done - slave_done.max(axis=1)
    return SimResult(
        arrivals=arrivals,
        response=response,
        master_part=master_part,
        network_part=network_part,
        slave_sojourn=slave_sojourn,
        kinds=kinds,
    )
