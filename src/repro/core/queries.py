"""Query workload generation (paper §5.1).

The paper issues 10,000-query sets at a Poisson arrival rate, mixing three
search-condition types and three top-k values (Fig 7(c)).  We generate the
same shape of workload over the synthetic corpus: keywords drawn Zipf-like
(so posting-list lengths vary realistically), siteIds drawn from the site
distribution, and exponential inter-arrival gaps.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import QueryBatch, make_query_batch
from repro.core.index import IndexMeta
from repro.core.perfmodel import QueryMix


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    sct: str                 # "single" | "multiple" | "limited"
    k: int                   # 10 | 50 | 1000
    terms: tuple[int, ...]
    site: int | None
    arrival: float           # seconds since stream start


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_queries: int = 1000
    arrival_rate: float = 100.0       # queries/sec (Poisson)
    zipf_s: float = 1.1
    max_terms: int = 3
    seed: int = 0


def generate_workload(
    meta: IndexMeta, mix: QueryMix, cfg: WorkloadConfig
) -> list[QuerySpec]:
    rng = np.random.default_rng(cfg.seed)
    kinds = list(mix.qmr.keys())
    probs = np.array([mix.qmr[kk] for kk in kinds])
    choices = rng.choice(len(kinds), size=cfg.n_queries, p=probs)

    ranks = np.arange(1, meta.vocab_size + 1, dtype=np.float64)
    term_p = ranks ** (-cfg.zipf_s)
    term_p /= term_p.sum()

    gaps = rng.exponential(1.0 / cfg.arrival_rate, size=cfg.n_queries)
    arrivals = np.cumsum(gaps)

    out: list[QuerySpec] = []
    for i, ci in enumerate(choices):
        sct, k = kinds[ci]
        if sct == "single":
            nt = 1
        else:
            nt = int(rng.integers(2, cfg.max_terms + 1))
        terms = tuple(
            int(t) for t in rng.choice(meta.vocab_size, size=nt, replace=False,
                                       p=term_p)
        )
        site = int(rng.integers(0, meta.n_sites)) if sct == "limited" else None
        out.append(QuerySpec(sct, k, terms, site, float(arrivals[i])))
    return out


def batch_by_k(
    specs: list[QuerySpec],
    *,
    t_max: int = 4,
    meta: IndexMeta | None = None,
    strategy: str = "embed",
) -> dict[int, tuple[QueryBatch, list[QuerySpec]]]:
    """Group a workload into fixed-k QueryBatches (k is static in the jit)."""
    groups: dict[int, list[QuerySpec]] = {}
    for s in specs:
        groups.setdefault(s.k, []).append(s)
    out = {}
    for k, ss in groups.items():
        qb = make_query_batch(
            [(list(s.terms), s.site) for s in ss],
            t_max=t_max, meta=meta, strategy=strategy,
        )
        out[k] = (qb, ss)
    return out
