"""ODYS hybrid performance model (paper §4) — the analytic half.

Implements, verbatim, the paper's queuing model for masters and network:

- query model (§4.1.1): 3 search-condition types x k in {10,50,1000};
  every query is normalized into *unit queries* (single-keyword top-10);
- arrival rates (§4.1.2, Table 2) and weighted arrival rates
  (§4.1.3, Formulas (1)-(3));
- component service times (§4.1.4, Formulas (4)-(8)) with the paper's
  measured constants (Table 3) shipped as :data:`PAPER_TABLE3`;
- M/D/1 queue lengths and sojourn times (§4.1.5, Formulas (9)-(16));
- total response time (§4.3, Formula (17)): the larger of the master's and
  the network's total sojourn, plus the expected **slave max time**
  (estimated experimentally — the hybrid's other half, in
  :mod:`repro.core.slave_max`).

All times are in **seconds**.  This module is deliberately pure
Python/numpy — it is capacity-planning mathematics, identical on any
hardware, and is reused to project LM serving capacity
(:mod:`repro.serving.capacity`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping

KS = (10, 50, 1000)
SCTS = ("single", "multiple", "limited")

MS = 1e-3
US = 1e-6


@dataclasses.dataclass(frozen=True)
class MasterParams:
    """Paper Formulas (4)-(8) constants (Table 3, master rows)."""

    T_parent_proc: float
    T_child_proc: float
    T_master_rpc: Mapping[int, float]          # per top-k
    t_comparison: float                         # loser-tree compare
    t_base: float                               # per-result base cost
    t_per_context_switch: float
    ncs_base: Mapping[int, float]
    ncs_per_slave: Mapping[int, float]
    alpha: float = 0.25                         # CPU : memory-bus split

    def T_merge(self, k: int, ns: int) -> float:
        """Formula (7): loser-tree merge cost at the master."""
        return k * (math.ceil(math.log2(ns)) * self.t_comparison + self.t_base)

    def T_context_switch(self, k: int, ns: int) -> float:
        """Formula (8)."""
        return self.t_per_context_switch * (
            self.ncs_base[k] + ns * self.ncs_per_slave[k]
        )

    def ST_master(self, k: int, ns: int) -> float:
        """Formula (4): total master service time for a top-k query."""
        return (
            self.T_parent_proc
            + (self.T_child_proc + self.T_master_rpc[k]) * ns
            + self.T_merge(k, ns)
            + self.T_context_switch(k, ns)
        )

    def ST_master_cpu(self, k: int, ns: int) -> float:
        """Formula (5)."""
        return self.ST_master(k, ns) * self.alpha

    def ST_master_membus(self, k: int, ns: int) -> float:
        """Formula (6)."""
        return self.ST_master(k, ns) * (1.0 - self.alpha)

    def w_master(self, k: int, ns: int) -> float:
        """Master weight of a top-k query in unit queries (§4.1.3)."""
        return self.ST_master(k, ns) / self.ST_master(10, ns)


@dataclasses.dataclass(frozen=True)
class NetworkParams:
    ST_network: Mapping[int, float]             # per top-k (Table 3)

    def w_network(self, k: int) -> float:
        return self.ST_network[k] / self.ST_network[10]


#: Table 3 of the paper, verbatim.
PAPER_TABLE3_MASTER = MasterParams(
    T_parent_proc=1.516 * MS,
    T_child_proc=0.0181 * MS,
    T_master_rpc={10: 0.01 * MS, 50: 0.011 * MS, 1000: 0.031 * MS},
    t_comparison=0.191 * US,
    t_base=0.28 * US,
    t_per_context_switch=15.995 * US,
    ncs_base={10: 80.869, 50: 80.869, 1000: 139.903},
    ncs_per_slave={10: 1.991, 50: 1.991, 1000: 3.444},
    alpha=0.25,  # §5.1: fitted on the five-node system
)

PAPER_TABLE3_NETWORK = NetworkParams(
    ST_network={10: 0.129 * MS, 50: 0.222 * MS, 1000: 0.318 * MS},
)


@dataclasses.dataclass(frozen=True)
class QueryMix:
    """qmr(sct, k) of §4.1.1/Fig 7(c).

    The paper's figure does not publish exact ratios; the default below is
    our documented assumption (single-keyword dominant, top-10 dominant) —
    it is a *parameter*, and every benchmark prints the mix used.
    """

    qmr: Mapping[tuple[str, int], float]

    def __post_init__(self):
        s = sum(self.qmr.values())
        assert abs(s - 1.0) < 1e-9, f"query mix must sum to 1, got {s}"

    def ratio_k(self, k: int) -> float:
        return sum(v for (sct, kk), v in self.qmr.items() if kk == k)


SINGLE_10_ONLY = QueryMix({("single", 10): 1.0})

QUERY_MIX_DEFAULT = QueryMix(
    {
        ("single", 10): 0.30, ("single", 50): 0.10, ("single", 1000): 0.05,
        ("multiple", 10): 0.20, ("multiple", 50): 0.10, ("multiple", 1000): 0.05,
        ("limited", 10): 0.12, ("limited", 50): 0.05, ("limited", 1000): 0.03,
    }
)


# ---------------------------------------------------------------------------
# M/D/1 queue (Formula (9)); deterministic service => E[ST^2] = ST^2.
# ---------------------------------------------------------------------------

def md1_queue_length(lam: float, st: float) -> float:
    """Formula (9).  Requires utilization rho = lam*st < 1."""
    rho = lam * st
    if rho >= 1.0:
        return math.inf
    return (lam**2 * st**2) / (2.0 * (1.0 - rho)) + rho


def sojourn(lam: float, st: float) -> float:
    """Formula (13): E[X] = L / lambda (per unit query)."""
    if lam <= 0.0:
        return st
    length = md1_queue_length(lam, st)
    return length / lam


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One ODYS set: nm masters (ncm CPUs each), ns slaves, nh hubs.

    ``nps``: Odysseus processes per slave — the paper's §5.1 runs 100 per
    node, making each slave a c-server queue (this is what lets a 5-node
    system absorb 266 q/s broadcast to every slave)."""

    nm: int = 4
    ncm: int = 4
    ns: int = 300
    nh: int = 11
    nps: int = 100


@dataclasses.dataclass(frozen=True)
class OdysPerfModel:
    master: MasterParams = PAPER_TABLE3_MASTER
    network: NetworkParams = PAPER_TABLE3_NETWORK

    # -- weighted arrival rates: Formulas (1)-(3) ---------------------------
    def mix_weight_master(self, mix: QueryMix, ns: int) -> float:
        return sum(
            self.master.w_master(k, ns) * mix.ratio_k(k) for k in KS
        )

    def mix_weight_network(self, mix: QueryMix) -> float:
        return sum(self.network.w_network(k) * mix.ratio_k(k) for k in KS)

    def lambda_master_cpu(self, lam: float, c: ClusterConfig, mix: QueryMix) -> float:
        """Formula (1)."""
        return lam / (c.ncm * c.nm) * self.mix_weight_master(mix, c.ns)

    def lambda_master_membus(self, lam: float, c: ClusterConfig, mix: QueryMix) -> float:
        """Formula (2)."""
        return lam / c.nm * self.mix_weight_master(mix, c.ns)

    def lambda_network(self, lam: float, c: ClusterConfig, mix: QueryMix) -> float:
        """Formula (3)."""
        return (c.ns / c.nh) * lam * self.mix_weight_network(mix)

    # -- sojourn times: Formulas (10)-(16) ----------------------------------
    def x_master_cpu(self, lam, c, mix, k: int) -> float:
        lam_w = self.lambda_master_cpu(lam, c, mix)
        x_unit = sojourn(lam_w, self.master.ST_master_cpu(10, c.ns))
        return x_unit * self.master.w_master(k, c.ns)

    def x_master_membus(self, lam, c, mix, k: int) -> float:
        lam_w = self.lambda_master_membus(lam, c, mix)
        x_unit = sojourn(lam_w, self.master.ST_master_membus(10, c.ns))
        return x_unit * self.master.w_master(k, c.ns)

    def x_network(self, lam, c, mix, k: int) -> float:
        lam_w = self.lambda_network(lam, c, mix)
        x_unit = sojourn(lam_w, self.network.ST_network[10])
        return (c.ns / c.nh) * x_unit * self.network.w_network(k)

    def master_network_time(self, lam, c, mix, k: int) -> float:
        """max(master, network) part of Formula (17)."""
        m = self.x_master_cpu(lam, c, mix, k) + self.x_master_membus(lam, c, mix, k)
        n = self.x_network(lam, c, mix, k)
        return max(m, n)

    # -- Formula (17) --------------------------------------------------------
    def total_response_time(
        self,
        lam: float,
        c: ClusterConfig,
        mix: QueryMix,
        slave_max_time: Callable[[str, int, float, int], float],
    ) -> float:
        """Mix-averaged t_parallel: queuing part + experimental slave max.

        ``slave_max_time(sct, k, lam, ns)`` is the hybrid's experimental
        half (partitioning method — core/slave_max.py).
        """
        total = 0.0
        for (sct, k), ratio in mix.qmr.items():
            if ratio == 0.0:
                continue
            t = self.master_network_time(lam, c, mix, k) + slave_max_time(
                sct, k, lam, c.ns
            )
            total += ratio * t
        return total

    def max_stable_load(self, c: ClusterConfig, mix: QueryMix) -> float:
        """Largest arrival rate with every queue's utilization < 1."""
        def util(lam):
            return max(
                self.lambda_master_cpu(lam, c, mix)
                * self.master.ST_master_cpu(10, c.ns),
                self.lambda_master_membus(lam, c, mix)
                * self.master.ST_master_membus(10, c.ns),
                self.lambda_network(lam, c, mix) * self.network.ST_network[10],
            )
        lo, hi = 0.0, 1e7
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if util(mid) < 1.0:
                lo = mid
            else:
                hi = mid
        return lo


def engine_cluster(ns: int, n_sets: int = 1) -> ClusterConfig:
    """ClusterConfig of OUR in-process JAX engine: each replicated set is a
    single-CPU master pipeline over ``ns`` mesh shards, with no hub tier —
    used when fitting/projecting against live measurements
    (:mod:`repro.core.calibrate`) rather than the paper's 5-node system."""
    return ClusterConfig(nm=n_sets, ncm=1, ns=ns, nh=1, nps=1)


def estimation_error(estimated: float, measured: float) -> float:
    """Formula (18)."""
    return abs(estimated - measured) / measured


def nodes_for_service(
    total_queries_per_day: float, queries_per_day_per_set: float, c: ClusterConfig
) -> tuple[int, int]:
    """Paper §5.2.4 arithmetic: (#sets, #nodes) to carry a query load."""
    sets = math.ceil(total_queries_per_day / queries_per_day_per_set)
    return sets, sets * (c.nm + c.ns)


def per_day(queries_per_sec: float) -> float:
    return queries_per_sec * 86400.0


def per_sec(queries_per_day: float) -> float:
    return queries_per_day / 86400.0
