from repro.data.corpus import Corpus, CorpusConfig, generate_corpus

__all__ = ["Corpus", "CorpusConfig", "generate_corpus"]
