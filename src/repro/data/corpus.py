"""Synthetic web corpus generator for the ODYS reproduction.

The paper crawls 114M real web pages; offline we synthesize a corpus whose
*statistics* match what the engine cares about:

- term frequencies follow a Zipf law (posting-list lengths are power-law
  distributed, which is what makes posting skipping worthwhile);
- documents carry a PageRank-style query-independent score; docIDs are
  assigned *in rank order* (docID 0 = best), so posting lists — which store
  ascending docIDs — are simultaneously in rank order (DESIGN.md §2);
- every document belongs to a site (Zipf-sized sites) for the
  limited-search / attribute-embedding experiments (paper Fig 1(c)/(d), Fig 4).

Everything here is host-side numpy: it is the "crawl + load" stage of the
pipeline and feeds :mod:`repro.core.index`.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 10_000
    vocab_size: int = 2_000
    mean_doc_len: int = 64
    zipf_s: float = 1.1           # term-frequency skew
    n_sites: int = 100
    site_zipf_s: float = 1.2      # site-size skew
    seed: int = 0


@dataclasses.dataclass
class Corpus:
    """Flat CSR of documents -> unique term ids, plus per-doc metadata.

    ``doc_terms[doc_offsets[d]:doc_offsets[d+1]]`` are the *unique* terms of
    doc ``d`` (an inverted index only needs set membership per doc; offsets
    within a page are not modeled — the paper's postings carry offsets only
    for phrase queries, which ODYS's experiments do not exercise).
    """

    doc_offsets: np.ndarray      # int64[n_docs+1]
    doc_terms: np.ndarray        # int32[nnz]
    doc_site: np.ndarray         # int32[n_docs], site id per doc
    n_docs: int
    vocab_size: int
    n_sites: int

    def terms_of(self, d: int) -> np.ndarray:
        return self.doc_terms[self.doc_offsets[d]:self.doc_offsets[d + 1]]


def _zipf_probs(n: int, s: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


def generate_corpus(cfg: CorpusConfig) -> Corpus:
    """Generate a synthetic corpus. docIDs come out already rank-ordered.

    PageRank rank-ordering is *implicit*: we simply declare the generation
    order to be rank order (doc 0 best).  Nothing downstream depends on the
    actual score values, only on the order — exactly the paper's
    query-independent-ranking assumption (§3.1).
    """
    rng = np.random.default_rng(cfg.seed)

    # Per-doc unique-term counts: lognormal-ish around the mean, >= 1.
    lens = np.maximum(
        1, rng.poisson(lam=cfg.mean_doc_len, size=cfg.n_docs)
    ).astype(np.int64)
    offsets = np.zeros(cfg.n_docs + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])

    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_s)
    draws = rng.choice(cfg.vocab_size, size=int(offsets[-1]), p=probs).astype(
        np.int32
    )

    # Dedup within each doc (keep fixed layout by re-drawing is overkill;
    # instead sort per-doc and mask duplicates, then re-pack).
    doc_ids = np.repeat(np.arange(cfg.n_docs, dtype=np.int64), lens)
    order = np.lexsort((draws, doc_ids))
    sd, st = doc_ids[order], draws[order]
    keep = np.ones(st.shape[0], dtype=bool)
    keep[1:] = (st[1:] != st[:-1]) | (sd[1:] != sd[:-1])
    sd, st = sd[keep], st[keep]
    new_lens = np.bincount(sd, minlength=cfg.n_docs).astype(np.int64)
    new_offsets = np.zeros(cfg.n_docs + 1, dtype=np.int64)
    np.cumsum(new_lens, out=new_offsets[1:])

    site_probs = _zipf_probs(cfg.n_sites, cfg.site_zipf_s)
    doc_site = rng.choice(cfg.n_sites, size=cfg.n_docs, p=site_probs).astype(
        np.int32
    )

    return Corpus(
        doc_offsets=new_offsets,
        doc_terms=st.astype(np.int32),
        doc_site=doc_site,
        n_docs=cfg.n_docs,
        vocab_size=cfg.vocab_size,
        n_sites=cfg.n_sites,
    )
