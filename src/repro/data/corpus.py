"""Synthetic web corpus generator for the ODYS reproduction.

The paper crawls 114M real web pages; offline we synthesize a corpus whose
*statistics* match what the engine cares about:

- term frequencies follow a Zipf law (posting-list lengths are power-law
  distributed, which is what makes posting skipping worthwhile);
- documents carry a PageRank-style query-independent score; docIDs are
  assigned *in rank order* (docID 0 = best), so posting lists — which store
  ascending docIDs — are simultaneously in rank order (DESIGN.md §2);
- every document belongs to a site (Zipf-sized sites) for the
  limited-search / attribute-embedding experiments (paper Fig 1(c)/(d), Fig 4).

For the *online-update* scenario (repro.indexing) this module also
synthesizes **mutation streams** — interleaved insert/delete/update ops
with the same Zipf term statistics as the base corpus — plus
:func:`apply_mutations`, which materializes the post-stream corpus
(deleted docs become empty docs so every surviving docID keeps its rank)
as the from-scratch-rebuild ground truth for merge-on-read parity tests.

Everything here is host-side numpy: it is the "crawl + load" stage of the
pipeline and feeds :mod:`repro.core.index`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 10_000
    vocab_size: int = 2_000
    mean_doc_len: int = 64
    zipf_s: float = 1.1           # term-frequency skew
    n_sites: int = 100
    site_zipf_s: float = 1.2      # site-size skew
    seed: int = 0


@dataclasses.dataclass
class Corpus:
    """Flat CSR of documents -> unique term ids, plus per-doc metadata.

    ``doc_terms[doc_offsets[d]:doc_offsets[d+1]]`` are the *unique* terms of
    doc ``d`` (an inverted index only needs set membership per doc; offsets
    within a page are not modeled — the paper's postings carry offsets only
    for phrase queries, which ODYS's experiments do not exercise).
    """

    doc_offsets: np.ndarray      # int64[n_docs+1]
    doc_terms: np.ndarray        # int32[nnz]
    doc_site: np.ndarray         # int32[n_docs], site id per doc
    n_docs: int
    vocab_size: int
    n_sites: int

    def terms_of(self, d: int) -> np.ndarray:
        return self.doc_terms[self.doc_offsets[d]:self.doc_offsets[d + 1]]


def _zipf_probs(n: int, s: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


def corpus_from_docs(
    docs: list[np.ndarray],
    sites,
    *,
    vocab_size: int,
    n_sites: int,
) -> Corpus:
    """Assemble a Corpus from per-doc term arrays + sites (docID = index)."""
    lens = np.array([d.shape[0] for d in docs], dtype=np.int64)
    offsets = np.zeros(len(docs) + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    terms = (
        np.concatenate(docs) if docs else np.zeros(0, dtype=np.int32)
    ).astype(np.int32)
    return Corpus(
        doc_offsets=offsets,
        doc_terms=terms,
        doc_site=np.asarray(sites, dtype=np.int32),
        n_docs=len(docs),
        vocab_size=vocab_size,
        n_sites=n_sites,
    )


def generate_corpus(cfg: CorpusConfig) -> Corpus:
    """Generate a synthetic corpus. docIDs come out already rank-ordered.

    PageRank rank-ordering is *implicit*: we simply declare the generation
    order to be rank order (doc 0 best).  Nothing downstream depends on the
    actual score values, only on the order — exactly the paper's
    query-independent-ranking assumption (§3.1).
    """
    rng = np.random.default_rng(cfg.seed)

    # Per-doc unique-term counts: lognormal-ish around the mean, >= 1.
    lens = np.maximum(
        1, rng.poisson(lam=cfg.mean_doc_len, size=cfg.n_docs)
    ).astype(np.int64)
    offsets = np.zeros(cfg.n_docs + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])

    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_s)
    draws = rng.choice(cfg.vocab_size, size=int(offsets[-1]), p=probs).astype(
        np.int32
    )

    # Dedup within each doc (keep fixed layout by re-drawing is overkill;
    # instead sort per-doc and mask duplicates, then re-pack).
    doc_ids = np.repeat(np.arange(cfg.n_docs, dtype=np.int64), lens)
    order = np.lexsort((draws, doc_ids))
    sd, st = doc_ids[order], draws[order]
    keep = np.ones(st.shape[0], dtype=bool)
    keep[1:] = (st[1:] != st[:-1]) | (sd[1:] != sd[:-1])
    sd, st = sd[keep], st[keep]
    new_lens = np.bincount(sd, minlength=cfg.n_docs).astype(np.int64)
    new_offsets = np.zeros(cfg.n_docs + 1, dtype=np.int64)
    np.cumsum(new_lens, out=new_offsets[1:])

    site_probs = _zipf_probs(cfg.n_sites, cfg.site_zipf_s)
    doc_site = rng.choice(cfg.n_sites, size=cfg.n_docs, p=site_probs).astype(
        np.int32
    )

    return Corpus(
        doc_offsets=new_offsets,
        doc_terms=st.astype(np.int32),
        doc_site=doc_site,
        n_docs=cfg.n_docs,
        vocab_size=cfg.vocab_size,
        n_sites=cfg.n_sites,
    )


# ---------------------------------------------------------------------------
# Mutation streams (online-update workload, repro.indexing)
# ---------------------------------------------------------------------------

class Mutation(NamedTuple):
    """One ingest operation.

    ``op`` is ``"insert"`` (terms+site, docid assigned by the writer),
    ``"delete"`` (docid only) or ``"update"`` (docid + new terms; ``site``
    is the new site, or None to keep the old one).
    """

    op: str
    docid: int | None
    terms: np.ndarray | None
    site: int | None


@dataclasses.dataclass(frozen=True)
class MutationConfig:
    n_ops: int = 100
    p_insert: float = 0.5
    p_delete: float = 0.2
    p_update: float = 0.3
    mean_doc_len: int = 32
    zipf_s: float = 1.1
    site_zipf_s: float = 1.2
    p_site_change: float = 0.25   # fraction of updates that move sites
    seed: int = 0


def _draw_terms(rng, cfg: MutationConfig, probs: np.ndarray) -> np.ndarray:
    n = max(1, int(rng.poisson(lam=cfg.mean_doc_len)))
    return np.unique(
        rng.choice(probs.shape[0], size=n, p=probs)
    ).astype(np.int32)


def generate_mutations(corpus: Corpus, cfg: MutationConfig) -> list[Mutation]:
    """Synthesize an interleaved insert/delete/update stream over ``corpus``.

    Deletes and updates target uniformly-random *live* docs (tracking the
    stream's own inserts and deletes); inserts draw term sets and sites
    from the same Zipf laws as the base corpus, so posting-list length
    statistics — what posting skipping and the delta capacity care about —
    stay representative while the stream runs.
    """
    rng = np.random.default_rng(cfg.seed)
    probs = np.array([cfg.p_insert, cfg.p_delete, cfg.p_update], np.float64)
    probs = probs / probs.sum()
    site_probs = _zipf_probs(corpus.n_sites, cfg.site_zipf_s)
    term_probs = _zipf_probs(corpus.vocab_size, cfg.zipf_s)

    # Empty docs are deletion tombstones (apply_mutations leaves them in
    # place to keep ranks stable) — never valid delete/update targets.
    live = [
        d for d in range(corpus.n_docs)
        if corpus.doc_offsets[d + 1] > corpus.doc_offsets[d]
    ]
    n_docs = corpus.n_docs
    out: list[Mutation] = []
    for _ in range(cfg.n_ops):
        kind = ["insert", "delete", "update"][rng.choice(3, p=probs)]
        if kind != "insert" and not live:
            kind = "insert"
        if kind == "insert":
            terms = _draw_terms(rng, cfg, term_probs)
            site = int(rng.choice(corpus.n_sites, p=site_probs))
            out.append(Mutation("insert", None, terms, site))
            live.append(n_docs)
            n_docs += 1
        elif kind == "delete":
            i = int(rng.integers(len(live)))
            gid = live.pop(i)
            out.append(Mutation("delete", gid, None, None))
        else:
            gid = live[int(rng.integers(len(live)))]
            terms = _draw_terms(rng, cfg, term_probs)
            site = (
                int(rng.choice(corpus.n_sites, p=site_probs))
                if rng.random() < cfg.p_site_change
                else None
            )
            out.append(Mutation("update", gid, terms, site))
    return out


def apply_mutations(corpus: Corpus, mutations: list[Mutation]) -> Corpus:
    """Materialize the post-stream corpus — the ground truth a from-scratch
    rebuild sees.  Deleted docs become *empty* docs (zero terms, site kept)
    so docIDs, and therefore ranks, never shift."""
    docs = [np.asarray(corpus.terms_of(d), np.int32) for d in range(corpus.n_docs)]
    sites = [int(x) for x in corpus.doc_site]
    for m in mutations:
        if m.op == "insert":
            docs.append(np.unique(np.asarray(m.terms, np.int32)))
            sites.append(int(m.site))
        elif m.op == "delete":
            docs[m.docid] = np.zeros(0, dtype=np.int32)
        elif m.op == "update":
            docs[m.docid] = np.unique(np.asarray(m.terms, np.int32))
            if m.site is not None:
                sites[m.docid] = int(m.site)
        else:
            raise ValueError(m.op)
    return corpus_from_docs(
        docs, sites, vocab_size=corpus.vocab_size, n_sites=corpus.n_sites
    )
