"""Token data pipeline for LM training.

Deterministic, shardable synthetic token stream (offline container: no
real corpora).  The stream is seeded by (epoch, step, host) so elastic
restarts resume exactly; per-host sharding matches the ``data`` axis
layout the trainer uses (each host feeds its local devices only — the
standard multi-pod input pipeline contract).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Markov-ish structure so the LM loss actually decreases.
    n_states: int = 64


class TokenStream:
    """Iterator of {tokens,labels} numpy batches for one host."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        rng = np.random.default_rng(cfg.seed)
        # Shared low-entropy transition table => learnable structure.
        self.table = rng.integers(
            0, cfg.vocab, size=(cfg.n_states, 8), dtype=np.int32
        )

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.cfg.seed, step, self.host_id)
        )
        B, S = self.local_batch, self.cfg.seq_len
        state = rng.integers(0, self.cfg.n_states, size=(B, 1))
        toks = np.empty((B, S + 1), dtype=np.int32)
        noise = rng.integers(0, 8, size=(B, S + 1))
        cur = state[:, 0]
        for t in range(S + 1):
            toks[:, t] = self.table[cur, noise[:, t]]
            cur = (cur + toks[:, t]) % self.cfg.n_states
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
