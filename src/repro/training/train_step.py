"""Training step: loss + grad + AdamW, with remat and gradient accumulation.

``make_train_step`` builds the jit-able step used by both the real trainer
(launch/train.py) and the dry-run (launch/dryrun.py lowers it abstractly).

Distributed-optimization knobs:
- ``remat``: rematerialize each layer group (activation checkpointing) —
  trades HLO_FLOPs up for HLO_bytes down; a §Perf lever.
- ``microbatches``: sequential gradient accumulation via lax.scan; the
  all-reduce of the summed gradient happens once per step (comm amortized
  over microbatches — the standard overlap/compression-adjacent trick that
  works on any fabric).
- gradients are averaged over the ``data``(+``pod``) axes implicitly by
  pjit on the loss mean; no hand-written collectives needed.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import train_loss
from repro.training.optimizer import AdamWConfig, OptState, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: AdamWConfig,
    *,
    remat: bool = True,   # kept for API compat; layer remat lives in the
    microbatches: int = 1,  # model (cfg.remat_layers) where the scan is.
):
    loss_fn = train_loss

    def step(state: TrainState, inputs: dict) -> tuple[TrainState, dict]:
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, cfg, inputs)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, inputs)

            def accum(carry, mb):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, cfg, mb)
                return (
                    loss_sum + l,
                    jax.tree.map(jnp.add, gsum, g),
                ), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zero), micro
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt, metrics = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics["loss"] = loss
        return TrainState(params, opt), metrics

    return step
