"""AdamW with global-norm clipping, in plain JAX.

Optimizer state is a params-shaped pytree, so the same PartitionSpec tree
used for parameters shards first/second moments (ZeRO-1-style when the
param itself is sharded over ``model``; fully replicated params get
replicated state — the launcher may additionally shard those over ``data``
via the param_specs override in launch/shardings.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment (params-shaped)
    nu: Any          # second moment (params-shaped)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.nu, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, mu, nu), metrics
