"""Sharded, atomic, manifest-based checkpointing (fault tolerance §7).

Works for any pytree (train state, ODYS index shards).  Layout:

    <dir>/step_000123/
        manifest.json            # tree structure + leaf dtypes/shapes
        shard_000.npz ...        # leaves, split round-robin by byte size

Writes go to ``<dir>/.tmp.step_X`` then ``os.rename`` (atomic on POSIX),
so a crash mid-write can never corrupt the latest checkpoint;
``latest_step`` simply ignores incomplete temp dirs.  Restore is
shard-parallel-friendly (each npz is independent) and validates the
manifest against the target tree structure.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *, n_shards: int = 4) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = os.path.join(directory, f".tmp.step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    arrays = [np.asarray(x) for x in leaves]

    # Round-robin leaves into shards by descending size (balance bytes).
    order = sorted(range(len(arrays)), key=lambda i: -arrays[i].nbytes)
    assignment = {}
    loads = [0] * n_shards
    for i in order:
        s = loads.index(min(loads))
        assignment[i] = s
        loads[s] += arrays[i].nbytes

    for s in range(n_shards):
        payload = {
            f"leaf_{i}": arrays[i] for i, ss in assignment.items() if ss == s
        }
        np.savez(os.path.join(tmp, f"shard_{s:03d}.npz"), **payload)

    manifest = {
        "step": step,
        "n_shards": n_shards,
        "n_leaves": len(arrays),
        "assignment": {str(i): s for i, s in assignment.items()},
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isfile(
            os.path.join(directory, d, "manifest.json")
        )
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree):
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves, treedef = _flatten(like_tree)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"target tree has {len(leaves)}"
        )
    out = [None] * len(leaves)
    for s in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{s:03d}.npz")) as z:
            for key in z.files:
                i = int(key.split("_")[1])
                out[i] = z[key]
    for i, (a, like) in enumerate(zip(out, leaves)):
        want = tuple(getattr(like, "shape", np.shape(like)))
        if tuple(a.shape) != want:
            raise ValueError(f"leaf {i}: shape {a.shape} != expected {want}")
    return jax.tree.unflatten(treedef, out)
